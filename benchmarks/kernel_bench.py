"""Microbenchmarks of the Pallas kernels (interpret mode on CPU — relative
structure only; the roofline story for TPU lives in launch/roofline.py) and
of the secure primitives' throughput."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run():
    rng = np.random.default_rng(0)
    rows = []
    n, d, k = 1024, 512, 128
    a64 = jnp.asarray(rng.integers(0, 1 << 64, (n, d), dtype=np.uint64))
    b64 = jnp.asarray(rng.integers(0, 1 << 64, (d, k), dtype=np.uint64))
    rows.append({"kernel": "ring_matmul_u64", "shape": f"{n}x{d}x{k}",
                 "us_per_call": round(_time(ops.ring_matmul, a64, b64), 0)})
    x = jnp.asarray(rng.normal(0, 1, (n, d)), jnp.float32)
    mu = jnp.asarray(rng.normal(0, 1, (k, d)), jnp.float32)
    rows.append({"kernel": "fused_esd", "shape": f"{n}x{d}x{k}",
                 "us_per_call": round(_time(ops.esd, x, mu), 0)})
    dmat = jnp.asarray(rng.normal(0, 1, (n, k)), jnp.float32)
    rows.append({"kernel": "argmin_onehot", "shape": f"{n}x{k}",
                 "us_per_call": round(_time(ops.argmin_onehot, dmat), 0)})
    xs = np.asarray(rng.normal(0, 1, (256, 2048)) *
                    (rng.random((256, 2048)) > 0.9), np.float32)
    y = jnp.asarray(rng.normal(0, 1, (2048, 8)), jnp.float32)
    t0 = time.perf_counter()
    ops.spmm_from_dense(xs, y).block_until_ready()
    rows.append({"kernel": "spmm_ell(0.9 sparse)", "shape": "256x2048x8",
                 "us_per_call": round((time.perf_counter() - t0) * 1e6, 0)})
    return rows


def derived(rows):
    return rows[0]["us_per_call"]
