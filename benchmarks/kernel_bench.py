"""Per-op xla-vs-pallas microbenchmarks of the ring-compute backend layer.

Each row times the SAME op through both backends (core/backend.py), so the
speedup column is measured, not asserted. On CPU the pallas kernels run in
interpret mode — expect them to LOSE there; the point of recording the pair
is the trajectory: the same harness on a TPU shows the real kernel wins
(roofline story in launch/roofline.py). Results land in
benchmarks/BENCH_kernels.json for the perf history.
"""
from __future__ import annotations

import json
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core.backend import KS_LEVELS, PallasBackend, XlaBackend
from repro.core.sparse import CSRMatrix
from repro.kernels import ops, ref
from repro.kernels.spmm import csr_to_ell

BENCH_PATH = os.path.join(os.path.dirname(__file__), "BENCH_kernels.json")


def _time_us(fn, *args, reps=3):
    out = fn(*args)
    jnp.asarray(out).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jnp.asarray(out).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def _row(op, shape, xla_us, pallas_us):
    return {"op": op, "shape": shape, "xla_us": round(xla_us, 0),
            "pallas_us": round(pallas_us, 0),
            "speedup_x": round(xla_us / max(pallas_us, 1e-9), 3)}


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    xla, pal = XlaBackend(), PallasBackend()
    rows = []

    # ---- ring_mm: the Beaver-recombination hot op -----------------------
    n, d, k = (256, 256, 128) if quick else (1024, 512, 128)
    a = jnp.asarray(rng.integers(0, 1 << 64, (n, d), dtype=np.uint64))
    b = jnp.asarray(rng.integers(0, 1 << 64, (d, k), dtype=np.uint64))
    rows.append(_row("ring_mm_u64", f"{n}x{d}x{k}",
                     _time_us(xla.ring_mm, a, b),
                     _time_us(pal.ring_mm, a, b)))

    # ---- ring_spmm: Protocol-2 step-2 local compute ---------------------
    # ELL pack happens ONCE outside the timed region (it is offline layout
    # work), so the columns compare kernel vs kernel, not pack+kernel.
    ns, ds, ks = (128, 1024, 8) if quick else (256, 2048, 8)
    xs = rng.integers(0, 1 << 64, (ns, ds), dtype=np.uint64) \
        * (rng.random((ns, ds)) > 0.9)
    csr = CSRMatrix.from_dense(xs.astype(np.uint64))
    y = rng.integers(0, 1 << 64, (ds, ks), dtype=np.uint64)
    blocks, idx, counts = csr_to_ell(csr.indptr, csr.indices, csr.data,
                                     csr.shape)
    ell = (jnp.asarray(blocks), jnp.asarray(idx), jnp.asarray(counts),
           jnp.asarray(y))
    rows.append(_row("ring_spmm_u64(0.9 sparse)", f"{ns}x{ds}x{ks}",
                     _time_us(xla.ring_spmm, *ell),
                     _time_us(pal.ring_spmm, *ell)))

    # reference-fit shape: Protocol 2's X @ mu^T product at n=1024, k=8
    nr, dr, kr = 1024, 512, 8
    xr = rng.integers(0, 1 << 64, (nr, dr), dtype=np.uint64) \
        * (rng.random((nr, dr)) > 0.9)
    csr_r = CSRMatrix.from_dense(xr.astype(np.uint64))
    yr = rng.integers(0, 1 << 64, (dr, kr), dtype=np.uint64)
    br, ir, cr = csr_to_ell(csr_r.indptr, csr_r.indices, csr_r.data,
                            csr_r.shape)
    ell_r = (jnp.asarray(br), jnp.asarray(ir), jnp.asarray(cr),
             jnp.asarray(yr))
    rows.append(_row("ring_spmm_u64(0.9 sparse)", f"{nr}x{dr}x{kr}(kmeans)",
                     _time_us(xla.ring_spmm, *ell_r),
                     _time_us(pal.ring_spmm, *ell_r)))

    # ---- ks_fused: the CMP adder's local recombination ------------------
    # second shape is tournament-realistic: the (n, k/2) comparison tensor
    # of the first argmin round at the reference fit (n=1024, k=8)
    for nm, label in (((64, 128) if quick else (256, 128), None),
                      ((1024, 4), "1024x4(tournament)")):
        flat = [jnp.asarray(rng.integers(0, 1 << 64, nm, dtype=np.uint64))
                for _ in range(6)]
        lvls = [jnp.asarray(rng.integers(0, 1 << 64, (len(KS_LEVELS), 2) + nm,
                                         dtype=np.uint64)) for _ in range(5)]
        rows.append(_row("ks_fused", label or f"{nm[0]}x{nm[1]}",
                         _time_us(lambda: xla.ks_fused(*flat, *lvls,
                                                       party0=True)),
                         _time_us(lambda: pal.ks_fused(*flat, *lvls,
                                                       party0=True))))

    # ---- plaintext kernels (oracle vs pallas) ---------------------------
    ne, de, ke = (256, 256, 64) if quick else (1024, 512, 128)
    x = jnp.asarray(rng.normal(0, 1, (ne, de)), jnp.float32)
    mu = jnp.asarray(rng.normal(0, 1, (ke, de)), jnp.float32)
    rows.append(_row("fused_esd", f"{ne}x{de}x{ke}",
                     _time_us(ref.esd, x, mu), _time_us(ops.esd, x, mu)))
    dmat = jnp.asarray(rng.normal(0, 1, (ne, ke)), jnp.float32)
    rows.append(_row("argmin_onehot", f"{ne}x{ke}",
                     _time_us(ref.argmin_onehot, dmat),
                     _time_us(ops.argmin_onehot, dmat)))

    with open(BENCH_PATH, "w") as f:
        json.dump({"rows": rows, "note": "CPU interpret mode unless a TPU "
                   "is attached; see benchmarks/kernel_bench.py"}, f, indent=1)
    return rows


def derived(rows):
    """Headline: ring_mm xla/pallas speedup (>1 means pallas wins)."""
    return rows[0]["speedup_x"]
