"""True offline/online split: measured wall-clock of ALL four fit shapes.

The paper's headline claim is that a data-independent offline phase
"pre-computes almost all cryptographic operations" so the online phase is
much faster. This suite makes that split *measured*, not modelled, for every
partition x sparsity combo:

* baseline — `offline="on_demand"`: every Beaver triple synthesized
  host-side INSIDE the Lloyd loop, the whole protocol dispatched eagerly.
  `ondemand_loop_s` is the loop wall-clock with the dealer on the critical
  path (what online cost means when there is no preprocessing).
* pooled — `offline="pooled"`: the planner traces the triple schedule
  (cached across same-shape fits), the bulk dealer generates each
  shape-class in one stacked draw, and the online phase runs as TWO compiled
  launches per iteration (S1 distances+argmin, S3 update) consuming the
  pool — for the sparse combos with the Protocol-2 HE exchange as a host
  callback between the launches. `offline_s` covers plan + bulk gen (+ AOT
  compile on the first fit of a shape); `online_s` is the dealer-free loop.
* streamed — `offline="streamed"`: same online path, but pool tranches are
  generated per iteration on a background worker (double-buffered), so peak
  pool residency is independent of `iters` (`stream_peak_pool_MB` vs the
  bulk `pool_MB`).

All fits per combo are bit-exact (same seed, same per-class dealer
streams), which the suite asserts before reporting — the speedup cannot
come from computing something different.

Writes benchmarks/BENCH_online.json: one row per combo, plus a larger
n=4096 reference row in full mode. Reference config (full mode): n=1024,
k=8, d=32, 3 iterations, pallas backend; --quick drops to n=256 for the
per-PR smoke run (wired as `python -m benchmarks.run --only online_offline
--quick`).
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import make_blobs
from repro.core.kmeans import KMeansConfig, SecureKMeans

BENCH_PATH = os.path.join(os.path.dirname(__file__), "BENCH_online.json")

COMBOS = (("vertical", False), ("vertical", True),
          ("horizontal", False), ("horizontal", True))


def _split(x, partition):
    n, d = x.shape
    if partition == "vertical":
        return x[:, :d // 2], x[:, d // 2:]
    return x[:n // 2], x[n // 2:]


def _assert_bit_exact(r0, r1):
    np.testing.assert_array_equal(np.asarray(r0.centroids.s0, np.uint64),
                                  np.asarray(r1.centroids.s0, np.uint64))
    np.testing.assert_array_equal(np.asarray(r0.assignment.s1, np.uint64),
                                  np.asarray(r1.assignment.s1, np.uint64))


def _combo_row(partition, sparse, n, k, d, iters):
    x = make_blobs(n, d, k, seed=4, sparse_frac=0.8 if sparse else 0.0)
    a, b = _split(x, partition)
    base = dict(k=k, iters=iters, seed=3, backend="pallas",
                partition=partition, sparse=sparse)

    # cold pooled fit: pays the dry-run trace + S1/S3 AOT compile and warms
    # the kernel/plan/program caches the steady-state fits below reuse
    cold = SecureKMeans(KMeansConfig(**base, offline="pooled")).fit(a, b)

    res_od = SecureKMeans(KMeansConfig(**base)).fit(a, b)
    res_p = SecureKMeans(KMeansConfig(**base, offline="pooled")).fit(a, b)
    res_s = SecureKMeans(KMeansConfig(**base, offline="streamed")).fit(a, b)
    _assert_bit_exact(res_od, res_p)
    _assert_bit_exact(res_od, res_s)

    return {
        "partition": partition, "sparse": sparse,
        "n": n, "k": k, "d": d, "iters": iters, "backend": "pallas",
        "launches_per_iter": 2,            # S1 + S3 (Protocol 2 is a host
        # callback between them on the sparse combos)
        "ondemand_loop_s": round(res_od.loop_seconds, 4),
        "ondemand_online_excl_dealer_s": round(res_od.online_seconds, 4),
        "offline_cold_s": round(cold.offline_dealer_seconds, 4),
        "offline_warm_s": round(res_p.offline_dealer_seconds, 4),
        "offline_plan_warm_s": round(res_p.offline_plan_seconds, 4),
        "online_s": round(res_p.online_seconds, 4),
        "stream_online_s": round(res_s.online_seconds, 4),
        "pool_MB": round(res_p.dealer.pool_bytes / 2**20, 2),
        "stream_peak_pool_MB": round(res_s.dealer.pool_bytes / 2**20, 2),
        "he_s": round(res_p.he_seconds, 4),
        "speedup_vs_ondemand": round(
            res_od.loop_seconds / max(res_p.online_seconds, 1e-9), 2),
        "speedup_vs_ondemand_excl_dealer": round(
            res_od.online_seconds / max(res_p.online_seconds, 1e-9), 2),
        "stream_speedup_vs_ondemand": round(
            res_od.loop_seconds / max(res_s.online_seconds, 1e-9), 2),
    }


def run(quick: bool = False):
    n, k, d, iters = (256, 4, 16, 2) if quick else (1024, 8, 32, 3)
    rows = [_combo_row(part, sp, n, k, d, iters) for part, sp in COMBOS]
    if not quick:
        # larger reference fit: the streaming dealer's O(1-iteration)
        # residency is what makes this scale of pool practical
        rows.append(_combo_row("vertical", False, 4096, 8, 32, 3))
    with open(BENCH_PATH, "w") as f:
        json.dump({"rows": rows,
                   "note": "Per partition x sparsity combo. offline_cold_s "
                           "= plan trace + bulk gen + S1/S3 AOT compile on "
                           "a first-of-its-shape fit; offline_warm_s = the "
                           "same with plan/program caches hot (a second "
                           "identical fit). online_s = dealer-free loop, "
                           "TWO launches/iteration; sparse combos run "
                           "Protocol 2 host-side between the launches. "
                           "Baseline is the on-demand dealer (triples "
                           "synthesized inside the loop). Bit-exact fits, "
                           "same seed. stream_peak_pool_MB is the "
                           "double-buffered dealer's peak residency "
                           "(independent of iters)."},
                  f, indent=1)
    return rows


def derived(rows):
    """Headline: the WORST per-combo online speedup of the pooled split
    (regressions in any combo are visible, not averaged away)."""
    return min(r["speedup_vs_ondemand"] for r in rows)
