"""True offline/online split: measured wall-clock of the reference fit.

The paper's headline claim is that a data-independent offline phase
"pre-computes almost all cryptographic operations" so the online phase is
much faster. This suite makes that split *measured*, not modelled:

* baseline — `offline="on_demand"`: the PR-1 behaviour, every Beaver triple
  synthesized host-side INSIDE the Lloyd loop. `ondemand_loop_s` is the loop
  wall-clock with the dealer on the critical path (what online cost means
  when there is no preprocessing); `ondemand_online_excl_dealer_s` subtracts
  the dealer's own timer (the old accounting proxy).
* pooled — `offline="pooled"`: the planner traces the triple schedule, the
  bulk dealer generates each shape-class in one stacked draw, the pools are
  uploaded, and the dense-vertical online path runs as ONE compiled launch
  per iteration consuming the pool. `offline_s` covers plan + bulk gen +
  AOT compile; `online_s` is the dealer-free loop.

Both fits are bit-exact (same seed, same per-class dealer streams), which
the suite asserts before reporting — the speedup cannot come from computing
something different.

Writes benchmarks/BENCH_online.json. Reference config (full mode):
n=1024, k=8, d=32, 3 iterations, pallas backend.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import make_blobs
from repro.core.kmeans import KMeansConfig, SecureKMeans

BENCH_PATH = os.path.join(os.path.dirname(__file__), "BENCH_online.json")


def run(quick: bool = False):
    n, k, d, iters = (256, 4, 16, 2) if quick else (1024, 8, 32, 3)
    x = make_blobs(n, d, k, seed=4)
    a, b = x[:, :d // 2], x[:, d // 2:]
    base = dict(k=k, iters=iters, seed=3, backend="pallas")

    # warm-up: populate the kernel jit caches shared by both paths, so the
    # comparison is steady-state compute, not first-call compilation
    SecureKMeans(KMeansConfig(**base)).fit(a, b)

    res_od = SecureKMeans(KMeansConfig(**base)).fit(a, b)
    res_p = SecureKMeans(KMeansConfig(**base, offline="pooled")).fit(a, b)

    np.testing.assert_array_equal(
        np.asarray(res_od.centroids.s0, np.uint64),
        np.asarray(res_p.centroids.s0, np.uint64))
    np.testing.assert_array_equal(
        np.asarray(res_od.assignment.s1, np.uint64),
        np.asarray(res_p.assignment.s1, np.uint64))

    row = {
        "n": n, "k": k, "d": d, "iters": iters, "backend": "pallas",
        "ondemand_loop_s": round(res_od.loop_seconds, 4),
        "ondemand_online_excl_dealer_s": round(res_od.online_seconds, 4),
        "offline_s": round(res_p.offline_dealer_seconds, 4),
        "offline_plan_s": round(res_p.offline_plan_seconds, 4),
        "online_s": round(res_p.online_seconds, 4),
        "pool_MB": round(res_p.dealer.pool_bytes / 2**20, 2),
        "speedup_vs_ondemand": round(
            res_od.loop_seconds / max(res_p.online_seconds, 1e-9), 2),
        "speedup_vs_ondemand_excl_dealer": round(
            res_od.online_seconds / max(res_p.online_seconds, 1e-9), 2),
    }
    with open(BENCH_PATH, "w") as f:
        json.dump({"rows": [row],
                   "note": "offline_s = plan trace + bulk triple gen + AOT "
                           "compile of the single-launch iteration; "
                           "online_s = dealer-free Lloyd loop. Baseline is "
                           "the PR-1 on-demand dealer (triples synthesized "
                           "inside the loop). Bit-exact fits, same seed."},
                  f, indent=1)
    return [row]


def derived(rows):
    """Headline: online speedup of the pooled split over on-demand."""
    return rows[0]["speedup_vs_ondemand"]
