"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

import time

import numpy as np


def make_blobs(n, d, k, seed=0, sparse_frac=0.0):
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-4, 4, (k, d))
    lab = rng.integers(0, k, n)
    x = centers[lab] + rng.normal(0, 0.4, (n, d))
    if sparse_frac:
        x = x * (rng.random((n, d)) >= sparse_frac)
    return x


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
