"""Secure scoring service throughput — the serving-subsystem smoke.

A fitted model serves a stream of ragged arrival batches through
`repro.serve.ScoringService`: requests are coalesced, padded onto a small
compiled-geometry ladder, scored against the secret-shared centroids with
correlated randomness drained from a `TripleBank` provisioned once
up front. One row per deployment flavour (dense and sparse verticals —
the paper's payment-company + merchant split), reporting rows/s,
triples/request, bytes/request, and padding overhead.

Writes benchmarks/BENCH_serve.json; wired as
`python -m benchmarks.run --only serve --quick` (the per-PR smoke).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import make_blobs
from repro.core.kmeans import KMeansConfig, SecureKMeans
from repro.core.triples import TripleBank, serve_seed
from repro.serve import ScoringService

BENCH_PATH = os.path.join(os.path.dirname(__file__), "BENCH_serve.json")


def _serve_row(sparse: bool, *, n_train: int, d: int, k: int, ladder,
               n_requests: int, mean_batch: int, seed: int = 3) -> dict:
    d_a = d // 2
    x = make_blobs(n_train, d, k, seed=4, sparse_frac=0.8 if sparse else 0.0)
    km = SecureKMeans(KMeansConfig(k=k, iters=3, seed=seed, sparse=sparse,
                                   backend="auto", offline="pooled"))
    km.fit(x[:, :d_a], x[:, d_a:])

    bank = TripleBank(seed=serve_seed(seed))
    svc = ScoringService(km, bank=bank, ladder=ladder, with_scores=True,
                         d_a=d_a, d_b=d - d_a, provision_copies=n_requests)
    svc.warm()

    rng = np.random.default_rng(7)
    sizes = np.maximum(1, rng.poisson(mean_batch, n_requests))
    total_rows = int(sizes.sum())
    stream = make_blobs(total_rows, d, k, seed=11,
                        sparse_frac=0.8 if sparse else 0.0)
    off = 0
    for m in sizes:
        q = stream[off:off + m]
        off += m
        svc.submit(q[:, :d_a], q[:, d_a:])
    t0 = time.perf_counter()
    responses = svc.drain()
    wall = time.perf_counter() - t0
    assert len(responses) == n_requests

    row = {"mode": "sparse" if sparse else "dense",
           "partition": "vertical", "n_train": n_train, "d": d, "k": k,
           "ladder": list(svc.ladder.rungs), "mean_batch": int(mean_batch),
           "offline_provision_s": round(svc.offline_seconds, 4),
           "bank_gen_s": round(bank.gen_seconds, 4),
           "wall_s": round(wall, 4)}
    row.update(svc.stats.as_dict())
    return row


def run(quick: bool = False):
    if quick:
        kw = dict(n_train=256, d=16, k=4, ladder=(16, 64),
                  n_requests=10, mean_batch=12)
    else:
        kw = dict(n_train=1024, d=32, k=8, ladder=(32, 128, 512),
                  n_requests=32, mean_batch=48)
    rows = [_serve_row(False, **kw), _serve_row(True, **kw)]
    with open(BENCH_PATH, "w") as f:
        json.dump({"rows": rows,
                   "note": "ScoringService throughput: ragged arrival "
                           "batches coalesced and padded onto the compiled-"
                           "geometry ladder, triples drained from one "
                           "TripleBank provisioning pass (replenish_events "
                           "counts hot-path stock-outs). rows_per_s is "
                           "real (unpadded) transaction rows over the "
                           "drain wall-clock; bytes_per_request is the "
                           "per-launch protocol traffic replayed from the "
                           "predict plan."},
                  f, indent=1)
    return rows


def derived(rows):
    """Headline: dense-ladder serving throughput (rows/s)."""
    return rows[0]["rows_per_s"]
