"""Observability suite: the tracer's overhead budget, made measured.

DESIGN.md §15 promises the span tracer is free when disabled and <=5%
on the hot online walls when enabled. Both claims are asserted here, not
just reported:

* **Disabled**: a `span()` call on a disabled tracer is one attribute
  check returning a shared no-op context manager — measured here in
  ns/call next to a bare function call for scale.
* **Enabled**: the traced online-fit wall and serve-drain wall stay
  within `OVERHEAD_BUDGET` (1.05x) of the untraced runs, min-of-reps on
  both sides so a shared-CPU container hiccup doesn't fake a regression.
  The run asserts the budget — a tracer that leaks real time into the
  online path fails the suite.
* **Coverage**: the traced fit + drain must actually hit the
  instrumented seams — the span names recorded are reported and the
  load-bearing ones (fit, serve.drain, serve.request, bank.provision)
  asserted present.

Also exports the traced run's Chrome-trace JSON to
benchmarks/trace_sample.json — the CI artifact you can drop straight
into ui.perfetto.dev. Writes benchmarks/BENCH_obs.json. Wired as
`python -m benchmarks.run --only obs --quick`.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import make_blobs
from repro.core.kmeans import KMeansConfig, SecureKMeans
from repro.core.triples import TripleBank, serve_seed
from repro.obs import trace as _trace
from repro.serve import ScoringService

BENCH_PATH = os.path.join(os.path.dirname(__file__), "BENCH_obs.json")
TRACE_PATH = os.path.join(os.path.dirname(__file__), "trace_sample.json")
OVERHEAD_BUDGET = 1.05          # traced wall / untraced wall, asserted


def _noop_ns_per_call(calls: int = 200_000) -> dict:
    """ns/call of span() on a DISABLED tracer, with a bare function call
    timed the same way for scale."""
    t = _trace.Tracer(enabled=False)
    span = t.span
    t0 = time.perf_counter_ns()
    for _ in range(calls):
        with span("x"):
            pass
    disabled_ns = (time.perf_counter_ns() - t0) / calls

    def f():
        return None

    t0 = time.perf_counter_ns()
    for _ in range(calls):
        f()
    bare_ns = (time.perf_counter_ns() - t0) / calls
    return {"workload": "noop_span", "calls": calls,
            "disabled_span_ns": round(disabled_ns, 1),
            "bare_call_ns": round(bare_ns, 1)}


def _fit_once(a, b, k, iters, bs):
    cfg = KMeansConfig(k=k, iters=iters, seed=3, backend="pallas",
                       sparse=True, batch_size=bs, offline="pooled",
                       pipeline=True)
    return SecureKMeans(cfg).fit(a, b)


def _drain_once(km, res, stream, d, rung, requests):
    svc = ScoringService(km, res,
                         bank=TripleBank(seed=serve_seed(km.cfg.seed)),
                         rungs=(rung,), with_scores=True,
                         d_a=d // 2, d_b=d // 2,
                         provision_copies=requests, pipeline=True)
    svc.warm()
    for i in range(requests):
        q = stream[i * rung:(i + 1) * rung]
        svc.submit(q[:, :d // 2], q[:, d // 2:])
    t0 = svc.stats.online_seconds
    out = svc.drain()
    return out, svc.stats.online_seconds - t0


def run(quick: bool = False):
    # walls must be long enough that min-of-reps beats shared-CPU noise:
    # the budget is asserted, so a 40ms drain with +-20% jitter won't do
    n, bs, iters, reps = (2048, 512, 2, 5) if quick else (8192, 1024, 3, 5)
    k, d = 5, 24
    rung, requests = (64, 12) if quick else (128, 16)
    x = make_blobs(n, d, k, seed=4, sparse_frac=0.8)
    a, b = x[:, :d // 2], x[:, d // 2:]
    stream = make_blobs(rung * requests, d, k, seed=9, sparse_frac=0.8)

    tracer = _trace.get_tracer()
    was_enabled = tracer.enabled
    _trace.configure(enabled=False)
    _fit_once(a, b, k, iters, bs)               # warmup: compile + plans
    # one shared fitted model for every drain rep, plus one untimed
    # warmup drain so lazy predict-plan caches fill before timing
    km = SecureKMeans(KMeansConfig(k=k, iters=iters, seed=3,
                                   backend="pallas", sparse=True,
                                   batch_size=bs, offline="pooled",
                                   pipeline=True))
    res_serve = km.fit(a, b)
    _drain_once(km, res_serve, stream, d, rung, requests)

    fit_walls = {False: [], True: []}
    drain_walls = {False: [], True: []}
    res_by = {}
    out_by = {}
    for _ in range(reps):
        for enabled in (False, True):
            _trace.configure(enabled=enabled)
            tracer.reset()
            res = _fit_once(a, b, k, iters, bs)
            fit_walls[enabled].append(res.online_seconds)
            out, secs = _drain_once(km, res_serve, stream, d, rung,
                                    requests)
            drain_walls[enabled].append(secs)
            res_by[enabled] = res
            out_by[enabled] = out
    # tracing must not change a single output bit
    np.testing.assert_array_equal(
        np.asarray(res_by[False].centroids.s0, np.uint64),
        np.asarray(res_by[True].centroids.s0, np.uint64))
    for r0, r1 in zip(out_by[False], out_by[True]):
        np.testing.assert_array_equal(r0.labels, r1.labels)

    # min-of-reps both sides: least-perturbed observation of each mode
    fit_off, fit_on = min(fit_walls[False]), min(fit_walls[True])
    dr_off, dr_on = min(drain_walls[False]), min(drain_walls[True])
    fit_ratio = fit_on / max(fit_off, 1e-9)
    dr_ratio = dr_on / max(dr_off, 1e-9)
    assert fit_ratio <= OVERHEAD_BUDGET, \
        f"traced fit overhead x{fit_ratio:.3f} > {OVERHEAD_BUDGET}"
    assert dr_ratio <= OVERHEAD_BUDGET, \
        f"traced drain overhead x{dr_ratio:.3f} > {OVERHEAD_BUDGET}"

    # coverage: the traced runs must have hit the instrumented seams
    counts = tracer.span_counts()
    for need in ("fit", "serve.drain", "serve.request", "bank.provision"):
        assert counts.get(need, 0) > 0, f"span {need!r} never recorded"
    tracer.export_chrome(TRACE_PATH)
    noop = _noop_ns_per_call()
    _trace.configure(enabled=was_enabled)

    rows = [
        {"workload": "fit_online", "n": n, "d": d, "k": k, "iters": iters,
         "batch_size": bs, "reps": reps,
         "untraced_s": round(fit_off, 4), "traced_s": round(fit_on, 4),
         "overhead_x": round(fit_ratio, 3), "budget_x": OVERHEAD_BUDGET},
        {"workload": "serve_drain", "rung": rung, "requests": requests,
         "reps": reps,
         "untraced_s": round(dr_off, 4), "traced_s": round(dr_on, 4),
         "overhead_x": round(dr_ratio, 3), "budget_x": OVERHEAD_BUDGET},
        noop,
        {"workload": "coverage", "spans_recorded": sum(counts.values()),
         "distinct_span_names": len(counts),
         "span_counts": dict(sorted(counts.items())),
         "trace_artifact": os.path.basename(TRACE_PATH)},
    ]
    with open(BENCH_PATH, "w") as f:
        json.dump({"rows": rows,
                   "note": "overhead_x = min-of-reps traced wall over "
                           "min-of-reps untraced wall, asserted <= "
                           f"{OVERHEAD_BUDGET}x on both the online fit "
                           "and the serve drain; outputs asserted "
                           "bit-identical traced vs untraced. "
                           "disabled_span_ns is the cost of leaving the "
                           "instrumentation in a hot loop with tracing "
                           "off. trace_sample.json is the traced run's "
                           "Chrome-trace export (ui.perfetto.dev)."},
                  f, indent=1)
    return rows


def derived(rows):
    fit = [r for r in rows if r["workload"] == "fit_online"][0]
    dr = [r for r in rows if r["workload"] == "serve_drain"][0]
    cov = [r for r in rows if r["workload"] == "coverage"][0]
    return (f"fit x{fit['overhead_x']} drain x{dr['overhead_x']} "
            f"spans {cov['spans_recorded']}")
