"""Minibatch pipeline suite: overlap measured, residency measured.

Two claims from DESIGN.md §11 are made *measured* here, on the sparse-
vertical workload (the combo whose online path carries real host work —
the Protocol-2 exchanges — between launches):

* **Overlap**: a pipelined minibatch fit (`pipeline=True`: batch t+1's
  host exchange + tranche pin run while batch t's S1 launch is on device)
  is faster than the stream-identical sequential escape hatch
  (`pipeline=False`) on ONLINE wall-clock. Both fits are asserted
  bit-exact before timing is reported — the speedup cannot come from
  computing something different. The headline row uses `offline="pooled"`
  (randomness pregenerated, so online wall IS the host/device interleave);
  the streamed rows additionally report the tranche-wait stalls the
  overlap hides.
* **Residency**: with `offline="streamed"`, peak triple-pool residency is
  O(window x batch) — the same fit at 4x the rows holds the same peak pool
  bytes (`residency_ratio` ~ 1), which is what opens fits whose full pool
  would not fit in device memory.

Plus a serving row: `ScoringService.drain` with `pipeline` on/off over the
same request stream (request t+1's exchange + bank draw overlapping
request t's launch), responses asserted identical.

Writes benchmarks/BENCH_pipeline.json. Full mode: n=16384, batch 2048;
--quick: n=4096, batch 512 (wired as `python -m benchmarks.run
--only pipeline --quick`).
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import make_blobs
from repro.core.kmeans import KMeansConfig, SecureKMeans
from repro.core.triples import TripleBank, serve_seed
from repro.serve import ScoringService

BENCH_PATH = os.path.join(os.path.dirname(__file__), "BENCH_pipeline.json")


def _assert_bit_exact(r0, r1):
    np.testing.assert_array_equal(np.asarray(r0.centroids.s0, np.uint64),
                                  np.asarray(r1.centroids.s0, np.uint64))
    np.testing.assert_array_equal(np.asarray(r0.assignment.s1, np.uint64),
                                  np.asarray(r1.assignment.s1, np.uint64))


def _fit_row(n, d, k, iters, batch_size, offline, reps=3):
    x = make_blobs(n, d, k, seed=4, sparse_frac=0.8)
    a, b = x[:, :d // 2], x[:, d // 2:]
    base = dict(k=k, iters=iters, seed=3, backend="pallas", sparse=True,
                batch_size=batch_size)
    # warmup: compile the batch/finalize programs, trace the stage plans
    SecureKMeans(KMeansConfig(**base, offline=offline)).fit(a, b)
    res, secs = {}, {False: [], True: []}
    for _ in range(reps):
        for pipe in (False, True):
            res[pipe] = SecureKMeans(
                KMeansConfig(**base, offline=offline,
                             pipeline=pipe)).fit(a, b)
            secs[pipe].append(res[pipe].online_seconds)
    _assert_bit_exact(res[False], res[True])
    # best-of-reps: the container's CPU time is shared, so min is the
    # least-perturbed observation of each mode
    seq, pipe = min(secs[False]), min(secs[True])
    row = {
        "workload": "fit", "offline": offline, "sparse": True,
        "partition": "vertical", "n": n, "k": k, "d": d, "iters": iters,
        "batch_size": batch_size,
        "batches_per_iter": -(-n // batch_size), "reps": reps,
        "online_sequential_s": round(seq, 4),
        "online_pipelined_s": round(pipe, 4),
        "pipeline_speedup": round(seq / max(pipe, 1e-9), 2),
        "peak_pool_MB": round(res[True].dealer.pool_bytes / 2**20, 2),
    }
    if offline == "streamed":
        row["tranche_wait_sequential_s"] = round(
            res[False].dealer.wait_seconds, 4)
        row["tranche_wait_pipelined_s"] = round(
            res[True].dealer.wait_seconds, 4)
    return row


def _serve_row(n_train, d, k, rung, requests):
    x = make_blobs(n_train, d, k, seed=7, sparse_frac=0.8)
    a, b = x[:, :d // 2], x[:, d // 2:]
    km = SecureKMeans(KMeansConfig(k=k, iters=2, seed=3, sparse=True,
                                   backend="pallas", offline="pooled"))
    res = km.fit(a, b)
    stream = make_blobs(rung * requests, d, k, seed=9, sparse_frac=0.8)
    outs, secs = {}, {}
    for pipe in (False, True):
        svc = ScoringService(km, res,
                             bank=TripleBank(seed=serve_seed(km.cfg.seed)),
                             rungs=(rung,), with_scores=True,
                             d_a=d // 2, d_b=d // 2,
                             provision_copies=requests, pipeline=pipe)
        svc.warm()
        for i in range(requests):
            q = stream[i * rung:(i + 1) * rung]
            svc.submit(q[:, :d // 2], q[:, d // 2:])
        t0 = svc.stats.online_seconds
        outs[pipe] = svc.drain()
        secs[pipe] = svc.stats.online_seconds - t0
    for r0, r1 in zip(outs[False], outs[True]):
        np.testing.assert_array_equal(r0.labels, r1.labels)
        np.testing.assert_array_equal(r0.scores, r1.scores)
    return {
        "workload": "serve", "sparse": True, "partition": "vertical",
        "n_train": n_train, "k": k, "d": d, "rung": rung,
        "requests": requests,
        "drain_sequential_s": round(secs[False], 4),
        "drain_pipelined_s": round(secs[True], 4),
        "pipeline_speedup": round(secs[False] / max(secs[True], 1e-9), 2),
    }


def run(quick: bool = False):
    n, bs, iters = (4096, 512, 2) if quick else (16384, 2048, 3)
    k, d = 8, 32
    rows = [_fit_row(n, d, k, iters, bs, "pooled")]
    # residency: SAME batch size at n and n/4 — the streamed peak pool
    # tracks the batch, so it must not move with n
    big = _fit_row(n, d, k, iters, bs, "streamed")
    small = _fit_row(n // 4, d, k, iters, bs, "streamed")
    big["residency_ratio_vs_quarter_n"] = round(
        big["peak_pool_MB"] / max(small["peak_pool_MB"], 1e-9), 2)
    rows += [big, small]
    rows.append(_serve_row(1024 if quick else 2048, d, k,
                           128 if quick else 256, 8 if quick else 12))
    import os as _os
    with open(BENCH_PATH, "w") as f:
        json.dump({"rows": rows, "host_cpus": _os.cpu_count(),
                   "note": "pipeline=True overlaps batch/request t+1's "
                           "host Protocol-2 exchange + tranche pin with "
                           "t's in-flight launch; pipeline=False is the "
                           "stream-identical sequential escape hatch "
                           "(asserted bit-exact before timing). Pooled fit "
                           "row = the online host/device interleave alone; "
                           "streamed rows add tranche-generation stalls "
                           "(wait_*) and show peak pool residency "
                           "independent of n at fixed batch "
                           "(residency_ratio_vs_quarter_n ~ 1 while n "
                           "grows 4x). CAVEAT on fit overlap: on a host "
                           "whose 'device' is the CPU itself (host_cpus "
                           "cores shared between XLA compute threads and "
                           "the protocol host work), host/device overlap "
                           "is zero-sum once XLA saturates the cores — "
                           "the fit rows then measure only the queue-gap "
                           "hiding (~1.0-1.2x here on 2 cores), while the "
                           "serve row's long host segments (pad, encode, "
                           "bank draw, reveal) overlap fully (>1.8x "
                           "measured). On an accelerator-backed device "
                           "the fit-side exchange overlap is the same "
                           "mechanism as the serve row's."},
                  f, indent=1)
    return rows


def derived(rows):
    """Headline: fit overlap x serve overlap (pooled fit row, serve row)."""
    serve = [r for r in rows if r["workload"] == "serve"][0]
    return (f"fit x{rows[0]['pipeline_speedup']} "
            f"serve x{serve['pipeline_speedup']}")
