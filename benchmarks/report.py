"""Format dryrun/roofline JSON into the EXPERIMENTS.md markdown tables.

    PYTHONPATH=src python -m benchmarks.report dryrun_results.json \
        roofline_results.json
"""
from __future__ import annotations

import json
import sys


def fmt(v, nd=3):
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1000 or abs(v) < 0.001:
            return f"{v:.2e}"
        return f"{v:.{nd}g}"
    return str(v)


def dryrun_table(path: str) -> str:
    rs = json.load(open(path))
    lines = ["| arch | shape | mesh | status | compile s | GFLOP/dev | "
             "arg GB/dev | peak GB/dev | link GB/dev |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rs:
        mem = r.get("memory", {})
        peak = mem.get("peak_memory_in_bytes") or (
            mem.get("argument_size_in_bytes", 0)
            + mem.get("temp_size_in_bytes", 0)
            + mem.get("output_size_in_bytes", 0))
        lines.append(
            f"| {r['arch']} | {r.get('shape','-')} "
            f"| {r.get('mesh_name', r.get('mesh','-'))} | {r['status']} "
            f"| {r.get('compile_s','-')} "
            f"| {fmt(r.get('flops_per_device', 0)/1e9)} "
            f"| {fmt(mem.get('argument_size_in_bytes', 0)/2**30)} "
            f"| {fmt(peak/2**30)} "
            f"| {fmt(r.get('collectives',{}).get('link_bytes',0)/2**30)} |")
    return "\n".join(lines)


def roofline_table(path: str) -> str:
    rs = json.load(open(path))
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | useful ratio | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for r in rs:
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                         f"{r.get('status')} | - | - |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt(r['compute_s'])} "
            f"| {fmt(r['memory_s'])} | {fmt(r['collective_s'])} "
            f"| **{r['dominant']}** | {fmt(r['useful_ratio'], 2)} "
            f"| {r['roofline_fraction']*100:.1f}% |")
    return "\n".join(lines)


if __name__ == "__main__":
    if len(sys.argv) > 1:
        print(dryrun_table(sys.argv[1]))
    if len(sys.argv) > 2:
        print()
        print(roofline_table(sys.argv[2]))
