"""Chaos matrix for the self-healing two-party runtime (DESIGN.md §16).

Every cell runs the SAME deterministic two-party fit (party A = engine,
party B = wire peer) under `launch/supervisor.py`, kills one or both
parties at a named protocol seam (`core/faultpoints.py`), optionally
overlays a wire-fault mix, and asserts the run still converges to the
UNKILLED fit's exact bytes:

* kill-points — fit.exchange1, fit.mid_s1, fit.s2_callback,
  fit.s3_partial, fit.finalize, fit.publish (party B is killed inside
  its serve loop, `wire.serve:K`, with K spread across the run);
* victims — A, B, or both;
* fault mixes — sever (scripted connection tears), drop+dup, corrupt
  (all CRC-recoverable; injected on incarnation 0 only, like the kills,
  so a restart doesn't re-die at the same seam forever).

Convergence is byte-exact: the six share arrays in A's --out npz
(mu0/mu1/c0/c1/p0/p1) plus the dealer counters and per-phase online
tallies must equal the clean reference run's. (Transport-level frame
counts legitimately differ across incarnations and are reported, not
compared.) Each row also reports MTTR — mean seconds from a death to
the next incarnation's readiness — and retry amplification: total
frames A sent across ALL incarnations (WIRE_STATS lines from survivors
+ the DYING line's stats from killed ones) over the clean run's frames.

Writes benchmarks/BENCH_chaos.json. Default is the 18-cell rotating
matrix; `--full` runs all 6x3x3 = 54 cells; `--quick` is the 3-cell CI
smoke (kill A mid-iteration, kill B at publish time, sever the resume
handshake itself), wired as
`python -m benchmarks.run --only chaos --quick`.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import statistics
import sys
import tempfile
import time

import numpy as np

BENCH_PATH = os.path.join(os.path.dirname(__file__), "BENCH_chaos.json")

KILL_POINTS = ("fit.exchange1", "fit.mid_s1", "fit.s2_callback",
               "fit.s3_partial", "fit.finalize", "fit.publish")
VICTIMS = ("A", "B", "both")
FAULT_NAMES = ("sever", "drop_dup", "corrupt")

# nth hit per A-side kill-point: batch-loop seams die in iteration 2
# (so a published checkpoint exists to resume from); per-iteration
# seams on their 2nd hit
A_NTH = {"fit.exchange1": 4, "fit.mid_s1": 4, "fit.s2_callback": 4,
         "fit.s3_partial": 4, "fit.finalize": 2, "fit.publish": 2}

# the shared tiny workload: 3 iterations x 3 minibatches, sequential
# executor (mid-iteration checkpoints are only legal there), pooled
# offline so restarts don't pay a cold dealer
FIT_ARGS = ["--n", "48", "--d", "4", "--k", "2", "--iters", "3",
            "--seed", "5", "--batch-size", "16", "--no-pipeline",
            "--offline", "pooled", "--checkpoint-every", "1",
            "--io-timeout", "120", "--peer-wait", "60"]


def _fault_flags(fault: str, seed: int) -> list[str]:
    if fault == "sever":
        return ["--fault-sever-at", "3,9"]
    if fault == "sever_handshake":
        # tear A's very first sends — the incarnation hello and the
        # resume negotiation ride frames 0..2
        return ["--fault-sever-at", "0,2"]
    if fault == "drop_dup":
        return ["--fault-drop", "0.03", "--fault-dup", "0.03",
                "--fault-seed", str(seed)]
    if fault == "corrupt":
        return ["--fault-corrupt", "0.03", "--fault-seed", str(seed)]
    return []


def _load_result(path: str):
    with np.load(path) as z:
        arrays = {k: z[k].copy()
                  for k in ("mu0", "mu1", "c0", "c1", "p0", "p1")}
        meta = json.loads(bytes(z["meta"]).decode())
    return arrays, meta


def _parse_stats(lines: list[str], role: str) -> list[dict]:
    """Every per-incarnation traffic dict a child printed: WIRE_STATS
    from incarnations that exited cleanly, the DYING line's stats= from
    killed ones."""
    out = []
    for line in lines:
        m = re.search(r"(?:WIRE_STATS\s+|\bstats=)(\{.*\})\s*$", line)
        if m:
            try:
                d = json.loads(m.group(1))
            except ValueError:
                continue
            if d.get("role") == role:
                out.append(d)
    return out


def _total(stats: list[dict], key: str) -> int:
    return sum(int(d.get(key, 0)) for d in stats)


def _cell(point, victim, fault, *, b_nth=6, fault_seed=0,
          timeout_s=300.0) -> dict:
    """One supervised two-party run; returns outputs + the timeline."""
    from repro.launch.supervisor import (RestartPolicy, SupervisedChild,
                                         child_env, free_port, python_argv)

    base_dir = os.environ.get("CHAOS_DIR") or None
    if base_dir:
        os.makedirs(base_dir, exist_ok=True)    # CI artifact collection
    td = tempfile.mkdtemp(prefix="chaos_", dir=base_dir)
    port = free_port()
    out_npz = os.path.join(td, "a.npz")
    a_base = ["--role", "A", "--port", str(port), *FIT_ARGS,
              "--out", out_npz,
              "--checkpoint-dir", os.path.join(td, "ck"), "--auto-resume"]
    if os.environ.get("CHAOS_TRACE"):
        # Perfetto trace from A's final (surviving) incarnation
        a_base += ["--trace-out", os.path.join(td, "trace_A.json")]
    b_base = ["--role", "B", "--port", str(port),
              "--io-timeout", "120", "--peer-wait", "60",
              "--state-dir", os.path.join(td, "bstate")]
    a_inc0, b_inc0 = [], []
    if victim in ("A", "both") and point:
        a_inc0 += ["--die-at", f"{point}:{A_NTH[point]}"]
    if victim in ("B", "both"):
        b_inc0 += ["--die-at", f"wire.serve:{b_nth}"]
    a_inc0 += _fault_flags(fault, fault_seed)

    def _argv_for(base, inc0):
        # kills and faults ride incarnation 0 only: a restarted party
        # runs clean and finishes the job
        def f(incarnation):
            extra = inc0 if incarnation == 0 else []
            return python_argv("repro.launch.two_party", *base, *extra)
        return f

    env = child_env()
    src = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                       os.pardir, "src"))
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    policy = RestartPolicy(max_restarts=5, backoff_s=0.05,
                           backoff_max_s=0.5)
    a = SupervisedChild("A", _argv_for(a_base, a_inc0), policy=policy,
                        terminal_codes=(0, 4), env=env,
                        ready_pattern=r"^LISTENING ",
                        log_path=os.path.join(td, "supervisor_A.log"))
    b = SupervisedChild("B", _argv_for(b_base, b_inc0), policy=policy,
                        terminal_codes=(0, 4), env=env,
                        log_path=os.path.join(td, "supervisor_B.log"))
    t0 = time.perf_counter()
    a.start()
    deadline = time.monotonic() + 60.0
    while time.monotonic() < deadline:       # B dials a bound port only
        if any("LISTENING" in line for line in a.lines):
            break
        if a.wait(0.0):                      # A already terminal: report
            break
        time.sleep(0.02)
    b.start()
    ok = a.wait(timeout_s) and b.wait(timeout_s)
    if not ok:
        a.stop()
        b.stop()
        raise RuntimeError(
            f"cell {point}/{victim}/{fault} hung past {timeout_s}s;\n"
            f"A tail:\n{a.tail()}\nB tail:\n{b.tail()}")
    wall = time.perf_counter() - t0
    return {"a": a, "b": b, "out_npz": out_npz, "wall": wall, "dir": td}


def _row(point, victim, fault, cell, clean) -> dict:
    a, b = cell["a"], cell["b"]
    tails = f"\nA tail:\n{a.tail()}\nB tail:\n{b.tail()}"
    name = f"{point or 'none'}/{victim or 'none'}/{fault}"
    assert a.returncode == 0, f"{name}: A terminal rc={a.returncode} " \
        f"({a.terminal_reason}){tails}"
    assert b.returncode == 0, f"{name}: B terminal rc={b.returncode} " \
        f"({b.terminal_reason}){tails}"
    if victim in ("A", "both"):
        assert a.restarts >= 1 and any("DYING point=" in line
                                       for line in a.lines), \
            f"{name}: A kill never fired{tails}"
    if victim in ("B", "both"):
        assert b.restarts >= 1 and any("DYING point=" in line
                                       for line in b.lines), \
            f"{name}: B kill never fired{tails}"
    arrays, meta = _load_result(cell["out_npz"])
    for k, ref in clean["arrays"].items():
        assert np.array_equal(arrays[k], ref), \
            f"{name}: array {k} diverged from the clean run{tails}"
    for k in ("counters", "fit_online", "predict_online"):
        assert meta[k] == clean["meta"][k], \
            f"{name}: {k} diverged: {meta[k]} != {clean['meta'][k]}"
    a_stats = _parse_stats(a.lines, "A")
    frames = _total(a_stats, "frames_sent")
    latencies = a.restart_latencies() + b.restart_latencies()
    amp = frames / clean["frames"] if clean["frames"] else 0.0
    return {
        "point": point or "none", "victim": victim or "none",
        "fault": fault,
        "restarts_a": a.restarts, "restarts_b": b.restarts,
        "incarnations": a.incarnation + b.incarnation + 2,
        "mttr_s": round(statistics.mean(latencies), 3) if latencies
        else None,
        "frames_sent_total": frames,
        "retry_amplification": round(amp, 3),
        "reconnects": _total(a_stats, "reconnects"),
        "retries": _total(a_stats, "retries"),
        "bit_exact": True,
        "wall_s": round(cell["wall"], 3),
    }


def _clean_reference() -> dict:
    """The unkilled, fault-free run every cell must reproduce exactly."""
    cell = _cell(None, None, "none")
    a, b = cell["a"], cell["b"]
    assert a.returncode == 0 and b.returncode == 0, \
        f"clean run failed\nA:\n{a.tail()}\nB:\n{b.tail()}"
    assert a.restarts == 0 and b.restarts == 0
    arrays, meta = _load_result(cell["out_npz"])
    a_stats = _parse_stats(a.lines, "A")
    b_stats = _parse_stats(b.lines, "B")
    return {"arrays": arrays, "meta": meta,
            "frames": _total(a_stats, "frames_sent"),
            "served": _total(b_stats, "served"),
            "wall": cell["wall"]}


def _matrix(full: bool) -> list[tuple]:
    cells = []
    for i, point in enumerate(KILL_POINTS):
        for j, victim in enumerate(VICTIMS):
            faults = FAULT_NAMES if full \
                else (FAULT_NAMES[(i + j) % len(FAULT_NAMES)],)
            for fault in faults:
                cells.append((point, victim, fault))
    return cells


# the 3-cell CI smoke: an engine death mid-iteration, a peer death at
# publish time, and connection tears during the resume handshake itself
QUICK_CELLS = [("fit.mid_s1", "A", "none"),
               ("fit.publish", "B", "none"),
               (None, None, "sever_handshake")]


def run(quick: bool = False, full: bool = False):
    clean = _clean_reference()
    served = clean["served"]
    rows = [{"point": "none", "victim": "none", "fault": "none",
             "restarts_a": 0, "restarts_b": 0, "incarnations": 2,
             "mttr_s": None, "frames_sent_total": clean["frames"],
             "retry_amplification": 1.0, "reconnects": 0, "retries": 0,
             "bit_exact": True, "wall_s": round(clean["wall"], 3)}]
    cells = QUICK_CELLS if quick else _matrix(full)
    for i, (point, victim, fault) in enumerate(cells):
        # B's kill frame: spread across the run, clamped so the armed
        # hit lands strictly before B's clean-run workload ends
        k = KILL_POINTS.index(point) if point in KILL_POINTS else 2
        b_nth = min(3 + 2 * k, max(2, served - 3))
        cell = _cell(point, victim, fault, b_nth=b_nth, fault_seed=i)
        rows.append(_row(point, victim, fault, cell, clean))
        print(f"  chaos[{i + 1}/{len(cells)}] "
              f"{rows[-1]['point']}/{rows[-1]['victim']}/{fault}: "
              f"restarts A={rows[-1]['restarts_a']} "
              f"B={rows[-1]['restarts_b']}, "
              f"mttr={rows[-1]['mttr_s']}s, "
              f"amp={rows[-1]['retry_amplification']}x", flush=True)
    with open(BENCH_PATH, "w") as f:
        json.dump({"rows": rows,
                   "note": "Chaos matrix: kill-points x victims x fault "
                           "mixes under launch/supervisor.py. Every cell "
                           "must converge to the clean run's exact share "
                           "bytes and online tallies. mttr_s = mean "
                           "death-to-ready seconds; retry_amplification "
                           "= A's frames across all incarnations over "
                           "the clean run's."},
                  f, indent=1)
    return rows


def derived(rows):
    """Headline: worst retry amplification + mean MTTR over kill cells."""
    killed = [r for r in rows if r["mttr_s"] is not None]
    if not killed:
        return ""
    amp = max(r["retry_amplification"] for r in rows)
    mttr = statistics.mean(r["mttr_s"] for r in killed)
    return f"mttr_mean={mttr:.2f}s amp_max={amp:.2f}x"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="3-cell CI smoke slice")
    ap.add_argument("--full", action="store_true",
                    help="all 54 cells instead of the rotating 18")
    args = ap.parse_args()
    rows = run(quick=args.quick, full=args.full)
    print(json.dumps(rows, indent=1))
    sys.exit(0)
