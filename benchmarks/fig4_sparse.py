"""Paper Fig. 4: sparse-optimization study.

(a) online cost vs feature dimension with/without Protocol 2 (measured run
    at a documented scale-down: n=10^5 vs the paper's 10^6 — single host,
    python; the comparison structure is dimension scaling, which is
    preserved).
(b) analytic online traffic vs sparsity degree {0, .5, .9, .99} and sample
    size 1e6..5e6 for the distance step (paper's choice), using the exact
    closed forms of both paths (sparse_matmul_comm_bytes is
    nnz-independent; the HE *time* model is nnz-proportional).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_blobs
from repro.core.channel import WAN
from repro.core.he import OU_COST_S
from repro.core.kmeans import KMeansConfig, SecureKMeans
from repro.core.sparse import (dense_ss_matmul_comm_bytes,
                               sparse_matmul_comm_bytes)


def run_a(quick: bool = False):
    rows = []
    n = 10**4 if quick else 10**5
    for d in (64, 128, 256):
        x = make_blobs(n, d, 2, seed=4, sparse_frac=0.2)
        half = d // 2
        out = {}
        for sparse in (False, True):
            res = SecureKMeans(KMeansConfig(k=2, iters=2, seed=3,
                                            sparse=sparse)
                               ).fit(x[:, :half], x[:, half:])
            b = res.log.total_bytes("online")
            r = res.log.total_rounds("online")
            t = WAN.time_s(b, r) + res.online_seconds + res.he_seconds
            out["sparse" if sparse else "dense"] = (b, t)
        rows.append({"n": n, "d": d,
                     "dense_online_MB": round(out["dense"][0] / 2**20, 1),
                     "sparse_online_MB": round(out["sparse"][0] / 2**20, 1),
                     "dense_online_wan_s": round(out["dense"][1], 1),
                     "sparse_online_wan_s": round(out["sparse"][1], 1)})
    return rows


def run_b():
    rows = []
    k, d = 2, 1024
    for n in (10**6, 2 * 10**6, 5 * 10**6):
        for sparsity in (0.0, 0.5, 0.9, 0.99):
            nnz = int(n * d * (1 - sparsity))
            dense_b = dense_ss_matmul_comm_bytes(n, d, k)
            sparse_b = sparse_matmul_comm_bytes(n, d, k)
            he_s = (d * k * OU_COST_S["enc"] + nnz * k * OU_COST_S["pmul"]
                    + nnz * k * OU_COST_S["add"]
                    + np.ceil(n * k / 8) * OU_COST_S["dec"])
            rows.append({
                "n": n, "sparsity": sparsity,
                "dense_online_GB": round(dense_b / 2**30, 1),
                "sparse_online_GB": round(sparse_b / 2**30, 2),
                "sparse_he_cpu_s": round(float(he_s), 0),
                "dense_wan_s": round(WAN.time_s(dense_b, 2), 0),
                "sparse_wan_s": round(WAN.time_s(sparse_b, 2)
                                      + float(he_s), 0)})
    return rows


def derived(rows_b):
    """Headline: traffic ratio dense/sparse at the paper's deployment point
    (n=1e6, sparsity .9)."""
    for r in rows_b:
        if r["n"] == 10**6 and r["sparsity"] == 0.9:
            return r["dense_online_GB"] / max(r["sparse_online_GB"], 1e-9)
    return float("nan")
