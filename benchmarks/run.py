"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # full
    PYTHONPATH=src python -m benchmarks.run --quick    # CI-sized

Prints one ``name,seconds,derived`` CSV line per suite plus the per-row
tables, and writes benchmarks/results.json consumed by EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()

    from benchmarks import (chaos_bench, fig2_online_offline,
                            fig3_vectorization, fig4_sparse, kernel_bench,
                            load_bench, obs_bench, offline_bench,
                            online_offline, pipeline_bench, q5_fraud,
                            serve_bench, table1_2, wire_bench)

    suites = {
        "table1_2_runtime_comm": lambda: table1_2.run(quick=args.quick),
        "fig2_online_offline": fig2_online_offline.run,
        "fig3_vectorization": fig3_vectorization.run,
        "fig4a_sparse_dim": lambda: fig4_sparse.run_a(quick=args.quick),
        "fig4b_sparse_degree": fig4_sparse.run_b,
        "q5_fraud_jaccard": lambda: q5_fraud.run(quick=args.quick),
        # `--only kernels_interpret --quick` is the CI smoke entry: per-op
        # xla-vs-pallas timings, persisted to benchmarks/BENCH_kernels.json
        "kernels_interpret": lambda: kernel_bench.run(quick=args.quick),
        # `--only online_offline --quick` is the per-PR perf smoke: measured
        # offline/online split of the pooled/streamed fits vs the on-demand
        # baseline for ALL FOUR partition x sparsity combos, persisted to
        # benchmarks/BENCH_online.json (full mode adds an n=4096 row)
        "online_offline": lambda: online_offline.run(quick=args.quick),
        # `--only serve --quick` is the serving-subsystem smoke: scoring-
        # service throughput over dense and sparse batch ladders, persisted
        # to benchmarks/BENCH_serve.json
        "serve": lambda: serve_bench.run(quick=args.quick),
        # `--only pipeline --quick` is the overlap smoke: pipelined vs
        # sequential minibatch fit + serve drain (bit-exact asserted) and
        # streamed peak-pool residency vs n, persisted to
        # benchmarks/BENCH_pipeline.json
        "pipeline": lambda: pipeline_bench.run(quick=args.quick),
        # `--only offline --quick` is the cold-start smoke: cold vs warm vs
        # bank-provisioned fit offline walls, batched-vs-legacy HE exchange
        # accounting + real-Paillier wall, and provisioning worker scaling,
        # persisted to benchmarks/BENCH_offline.json
        "offline": lambda: offline_bench.run(quick=args.quick),
        # `--only wire --quick` is the transport smoke: the same fit over
        # loopback frames, a real TCP socket, and emulated LAN/WAN latency
        # (bit-exact asserted), measured wall next to the NetModel
        # prediction, persisted to benchmarks/BENCH_wire.json
        "wire": lambda: wire_bench.run(quick=args.quick),
        # `--only load --quick` is the serving-plane smoke: open-loop
        # offered loads at 0.5x/1x/2x the closed-loop base rate (shed
        # rate, p99, replenish-stall occupancy) plus a two-process
        # kill/restart chaos leg (exactly-once, bit-exact), persisted to
        # benchmarks/BENCH_load.json
        "load": lambda: load_bench.run(quick=args.quick),
        # `--only obs --quick` is the observability smoke: tracing-on vs
        # tracing-off online-fit and serve-drain walls (<=1.05x asserted,
        # outputs bit-identical), the disabled-path ns/call, and span
        # coverage; persists benchmarks/BENCH_obs.json + the sample
        # Perfetto trace benchmarks/trace_sample.json
        "obs": lambda: obs_bench.run(quick=args.quick),
        # `--only chaos --quick` is the self-healing smoke: a 3-cell
        # slice of the kill-point x victim x fault-mix matrix under the
        # supervisor (kill A mid-iteration, kill B at publish, sever the
        # resume handshake), every cell asserted byte-exact against the
        # unkilled run; full mode sweeps the 18-cell rotating matrix;
        # persisted to benchmarks/BENCH_chaos.json with MTTR and
        # retry-amplification columns
        "chaos": lambda: chaos_bench.run(quick=args.quick),
    }
    derived_fns = {
        "table1_2_runtime_comm": table1_2.derived,
        "fig2_online_offline": fig2_online_offline.derived,
        "fig3_vectorization": fig3_vectorization.derived,
        "fig4b_sparse_degree": fig4_sparse.derived,
        "q5_fraud_jaccard": q5_fraud.derived,
        "kernels_interpret": kernel_bench.derived,
        "online_offline": online_offline.derived,
        "serve": serve_bench.derived,
        "pipeline": pipeline_bench.derived,
        "offline": offline_bench.derived,
        "wire": wire_bench.derived,
        "load": load_bench.derived,
        "obs": obs_bench.derived,
        "chaos": chaos_bench.derived,
    }
    if args.only:
        keep = set(args.only.split(","))
        suites = {k: v for k, v in suites.items() if k in keep}

    all_results = {}
    print("name,seconds,derived")
    for name, fn in suites.items():
        t0 = time.perf_counter()
        rows = fn()
        dt = time.perf_counter() - t0
        d = derived_fns.get(name, lambda r: "")(rows)
        all_results[name] = {"rows": rows, "seconds": round(dt, 1),
                             "derived": d}
        print(f"{name},{dt:.1f},{d}")
        for row in rows:
            print("   ", row)

    out = os.path.join(os.path.dirname(__file__), "results.json")
    with open(out, "w") as f:
        json.dump(all_results, f, indent=1, default=str)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
