"""Wire suite: the fit's network cost, measured instead of modelled.

Rows compare one fit workload across transports:

* **in_process** — no wire; the CommLog models the traffic (baseline).
* **loopback** — every online byte/round ships as real frames through
  `LoopbackTransport` + `ReliableChannel` (protocol overhead, no network).
* **socket** — the same frames over a real TCP connection (kernel stack),
  responder on a thread.
* **lan / wan** — loopback wrapped in `FaultyTransport.emulate(NetModel)`
  on BOTH endpoints: each frame pays rtt/2 + bytes/bandwidth, so the
  measured wall sits next to `NetModel`'s closed-form `time_estimate` —
  the paper's Table 1/2 network model, validated against an actual wire.

Every wired fit is asserted bit-exact (shares + online tallies) against
the in-process run before its timing is reported. Writes
benchmarks/BENCH_wire.json. --quick shrinks the workload and scales WAN
RTT down 10x (wired as `python -m benchmarks.run --only wire --quick`).
"""
from __future__ import annotations

import json
import os
import threading
import time

import numpy as np

from benchmarks.common import make_blobs
from repro.core.channel import (LAN, WAN, FaultyTransport,
                                LoopbackTransport, NetModel,
                                ReliableChannel, SocketTransport,
                                WireSession, serve_peer)
from repro.core.kmeans import KMeansConfig, SecureKMeans

BENCH_PATH = os.path.join(os.path.dirname(__file__), "BENCH_wire.json")


def _assert_bit_exact(r0, r1):
    np.testing.assert_array_equal(np.asarray(r0.centroids.s0, np.uint64),
                                  np.asarray(r1.centroids.s0, np.uint64))
    np.testing.assert_array_equal(np.asarray(r0.assignment.s1, np.uint64),
                                  np.asarray(r1.assignment.s1, np.uint64))
    assert r0.log.by_tag("online") == r1.log.by_tag("online")


def _loopback_session(net=None, **chan_kw):
    ta, tb = LoopbackTransport.pair()
    ea = FaultyTransport.emulate(ta, net) if net is not None else ta
    eb = FaultyTransport.emulate(tb, net) if net is not None else tb
    th = threading.Thread(target=serve_peer, args=(eb,),
                          kwargs={"idle_timeout_s": 600.0}, daemon=True)
    th.start()
    return WireSession(ReliableChannel(ea, **chan_kw)), th


def _socket_session(**chan_kw):
    srv = SocketTransport("listen", port=0, io_timeout_s=600.0)
    cli = SocketTransport("connect", port=srv.port, io_timeout_s=600.0)
    th = threading.Thread(target=serve_peer, args=(srv,),
                          kwargs={"idle_timeout_s": 600.0}, daemon=True)
    th.start()
    return WireSession(ReliableChannel(cli, **chan_kw)), th


def run(quick: bool = False) -> list:
    n, d, k, iters = (256, 8, 3, 2) if quick else (1024, 16, 4, 3)
    # --quick keeps CI under a minute: scale the WAN RTT down 10x (the
    # model row is scaled identically, so the comparison stays honest)
    wan = NetModel("WAN/10", WAN.bandwidth_bps, WAN.rtt_s / 10) if quick \
        else WAN
    x = make_blobs(n, d, k, seed=4)
    a, b = x[:, :d // 2], x[:, d // 2:]
    cfg = KMeansConfig(k=k, iters=iters, seed=3, offline="pooled",
                       backend="xla")
    SecureKMeans(cfg).fit(a, b)                      # compile warmup
    t0 = time.perf_counter()
    ref = SecureKMeans(cfg).fit(a, b)
    base_wall = time.perf_counter() - t0
    rows = [{"transport": "in_process", "fit_s": round(base_wall, 4),
             "model_s": 0.0,
             "online_bytes": ref.log.total_bytes("online"),
             "online_rounds": ref.log.total_rounds("online")}]

    chan_kw = dict(deadline_s=600.0, try_timeout_s=30.0)
    cases = [("loopback", lambda: _loopback_session(**chan_kw), None),
             ("socket", lambda: _socket_session(**chan_kw), None),
             ("lan_emulated", lambda: _loopback_session(LAN, **chan_kw),
              LAN),
             ("wan_emulated", lambda: _loopback_session(wan, **chan_kw),
              wan)]
    for name, mk, net in cases:
        ws, th = mk()
        t0 = time.perf_counter()
        r = SecureKMeans(cfg).fit(a, b, wire=ws)
        wall = time.perf_counter() - t0
        ws.bye()
        th.join(timeout=60)
        _assert_bit_exact(ref, r)
        # the NetModel's closed-form prediction of the NETWORK's share of
        # the wall (compute excluded) — the number the paper tables use
        model = 0.0 if net is None \
            else ref.log.time_estimate(net, "online")
        rows.append({"transport": name, "fit_s": round(wall, 4),
                     "model_s": round(model, 4),
                     "model_plus_compute_s": round(model + base_wall, 4),
                     "online_bytes": r.log.total_bytes("online"),
                     "online_rounds": r.log.total_rounds("online"),
                     "wire_payload_bytes": ws.payload_bytes,
                     "wire_rounds": ws.rounds})
    for row in rows:
        row.update(n=n, d=d, k=k, iters=iters, quick=bool(quick))
    with open(BENCH_PATH, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def derived(rows) -> str:
    by = {r["transport"]: r for r in rows}
    wan_row = by.get("wan_emulated")
    if not wan_row:
        return ""
    ratio = wan_row["fit_s"] / max(wan_row["model_plus_compute_s"], 1e-9)
    return (f"wan_wall={wan_row['fit_s']}s "
            f"model+compute={wan_row['model_plus_compute_s']}s "
            f"ratio={ratio:.2f}")


if __name__ == "__main__":
    for r in run(quick=True):
        print(r)
