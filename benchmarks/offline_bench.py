"""Offline cold-start suite: fit-plan bank, batched HE exchange, workers.

Measures the three legs of the PR that kills the offline cold start, per
partition x sparsity combo:

* **cold / warm / provisioned fit offline** — `offline_cold_s` is a
  first-of-its-shape pooled fit's offline wall (plan trace + bulk dealer
  generation + S1/S3 AOT compile); `offline_warm_s` a second identical fit
  (caches hot, generation still online-adjacent); `offline_provisioned_s`
  a fit served from a pre-provisioned fit-plan `TripleBank` — the fit-time
  offline work collapses to the plan lookup because ALL generation moved
  to `provision()` (whose wall is reported separately as the true offline
  cost, serial and 2-worker). All three fits are bit-exact (asserted).

* **HE exchange accounting** — modelled OU-2048 seconds of one Protocol-2
  exchange on the combo's own geometry, column-batched vs the legacy
  per-ciphertext loop (whose n*k `ct + int` mask additions are priced
  honestly as encryptions). The batched/legacy ratio is the sparse `he_s`
  headline.

* **real-Paillier wall** — measured wall-clock of the batched vs legacy
  exchange paths on a real 512-bit Paillier key (small geometry; bigint
  exponentiation, so minutes not microseconds at paper scale).

* **provisioning workers** — wall of `provision(workers=1)` vs
  `workers=2/4`. NOTE: this host may be single-core (the JSON records
  `cpu_count`); thread-pool scaling is only observable with >= 2 cores,
  the bit-exactness of the parallel split is what the tests enforce.

Writes benchmarks/BENCH_offline.json. Reference config (full): n=1024,
k=8, d=32, 3 iterations; --quick drops to n=256 (the CI smoke:
`python -m benchmarks.run --only offline --quick`).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import make_blobs
from repro.core import protocol as P
from repro.core.he import OU_COST_S, Paillier, SimulatedPHE
from repro.core.kmeans import KMeansConfig, SecureKMeans
from repro.core.sparse import (CSRMatrix, default_value_bits, he2ss_layout,
                               he2ss_op_counts, secure_sparse_matmul)
from repro.core.triples import TripleBank

BENCH_PATH = os.path.join(os.path.dirname(__file__), "BENCH_offline.json")

COMBOS = (("vertical", False), ("vertical", True),
          ("horizontal", False), ("horizontal", True))


def _split(x, partition):
    n, d = x.shape
    if partition == "vertical":
        return x[:, :d // 2], x[:, d // 2:]
    return x[:n // 2], x[n // 2:]


def _assert_bit_exact(r0, r1):
    np.testing.assert_array_equal(np.asarray(r0.centroids.s0, np.uint64),
                                  np.asarray(r1.centroids.s0, np.uint64))
    np.testing.assert_array_equal(np.asarray(r0.assignment.s1, np.uint64),
                                  np.asarray(r1.assignment.s1, np.uint64))


def _legacy_he_seconds(n, d, k, nnz, nrows_ne):
    """Modelled OU time of the per-ciphertext loop: d*k forward encrypts,
    nnz*k scalar pmuls, (nnz-rows)*k + n*k adds, n*k mask encryptions (the
    step-3 `ct + int` re-randomization the old accounting hid) and n*k
    decrypts."""
    return ((d * k + n * k) * OU_COST_S["enc"]
            + nnz * k * OU_COST_S["pmul"]
            + ((nnz - nrows_ne) * k + n * k) * OU_COST_S["add"]
            + n * k * OU_COST_S["dec"])


def _he_model_row(x_csr, k):
    n, d = x_csr.shape
    nrows_ne = int(np.count_nonzero(np.diff(x_csr.indptr)))
    lay = he2ss_layout(k, SimulatedPHE().plain_bits, default_value_bits(d))
    ops = he2ss_op_counts(n, d, x_csr.nnz, nrows_ne, lay)
    batched = sum(ops[o] * OU_COST_S[o] for o in OU_COST_S)
    legacy = _legacy_he_seconds(n, d, k, x_csr.nnz, nrows_ne)
    return {"he_batched_model_s": round(batched, 4),
            "he_legacy_model_s": round(legacy, 4),
            "he_model_speedup": round(legacy / max(batched, 1e-12), 2)}


def _combo_row(partition, sparse, n, k, d, iters):
    x = make_blobs(n, d, k, seed=4, sparse_frac=0.8 if sparse else 0.0)
    a, b = _split(x, partition)
    base = dict(k=k, iters=iters, seed=3, backend="pallas",
                partition=partition, sparse=sparse)

    # cold: first-of-its-shape fit pays trace + bulk gen + AOT compile
    cold = SecureKMeans(KMeansConfig(**base, offline="pooled")).fit(a, b)
    # warm: identical fit, plan/program caches hot — generation remains
    warm = SecureKMeans(KMeansConfig(**base, offline="pooled")).fit(a, b)

    # provisioned: ALL generation happens in provision() (the true offline
    # phase); the fit itself starts with a full bank
    km = SecureKMeans(KMeansConfig(**base, offline="pooled"))
    t0 = time.perf_counter()
    key, plan, _ = km.plan_fit(a.shape, b.shape)
    plan_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    bank = TripleBank(seed=3)
    bank.provision(key, plan)
    provision_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    bank2 = TripleBank(seed=3)
    bank2.provision(key, plan, workers=2)
    provision_2w_s = time.perf_counter() - t0
    prov = km.fit(a, b, dealer=bank.dealer(key))
    _assert_bit_exact(warm, prov)
    _assert_bit_exact(cold, prov)

    row = {
        "partition": partition, "sparse": sparse,
        "n": n, "k": k, "d": d, "iters": iters, "backend": "pallas",
        "offline_cold_s": round(cold.offline_dealer_seconds, 4),
        "offline_warm_s": round(warm.offline_dealer_seconds, 4),
        "plan_fit_s": round(plan_s, 4),
        "provision_serial_s": round(provision_s, 4),
        "provision_2workers_s": round(provision_2w_s, 4),
        "offline_provisioned_s": round(
            prov.offline_dealer_seconds + prov.offline_plan_seconds, 4),
        "provisioned_vs_cold": round(
            (prov.offline_dealer_seconds + prov.offline_plan_seconds)
            / max(cold.offline_dealer_seconds, 1e-9), 4),
        "online_s": round(prov.online_seconds, 4),
        "he_s": round(prov.he_seconds, 4),
    }
    if sparse:
        # one Protocol-2 exchange on this combo's own forward geometry
        row.update(_he_model_row(CSRMatrix.from_dense_real(a), k))
    return row


def _paillier_wall_row():
    """Measured batched vs legacy wall on a real 512-bit key (shares are
    asserted identical, so the speedup is pure exchange mechanics)."""
    rng = np.random.default_rng(17)
    n, d, k = 24, 16, 4
    xr = rng.uniform(-2, 2, (n, d)) * (rng.random((n, d)) > 0.7)
    x = CSRMatrix.from_dense_real(xr)
    yb = rng.integers(0, 1 << 63, (d, k)).astype(np.uint64)
    he = Paillier(512)
    t0 = time.perf_counter()
    zb = secure_sparse_matmul(P.make_ctx(5), x, yb, he, batched=True)
    batched_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    zl = secure_sparse_matmul(P.make_ctx(5), x, yb, he, batched=False)
    legacy_s = time.perf_counter() - t0
    np.testing.assert_array_equal(np.asarray(zb.s0), np.asarray(zl.s0))
    return {"n": n, "d": d, "k": k, "key_bits": 512,
            "nnz": int(x.nnz),
            "paillier_batched_s": round(batched_s, 3),
            "paillier_legacy_s": round(legacy_s, 3),
            "paillier_speedup": round(legacy_s / max(batched_s, 1e-9), 2)}


def _worker_scaling_row(n, k, d):
    x = make_blobs(n, d, k, seed=4, sparse_frac=0.8)
    a, b = _split(x, "vertical")
    km = SecureKMeans(KMeansConfig(k=k, iters=3, seed=3, sparse=True,
                                   backend="pallas", offline="pooled"))
    key, plan, _ = km.plan_fit(a.shape, b.shape)
    TripleBank(seed=3).provision(key, plan)   # warmup: dispatch caches etc.
    walls = {}
    for w in (1, 2, 4):
        t0 = time.perf_counter()
        bank = TripleBank(seed=3)
        bank.provision(key, plan, copies=2, workers=w)
        walls[w] = time.perf_counter() - t0
    return {"plan_requests": len(plan), "copies": 2,
            "cpu_count": os.cpu_count(),
            "provision_1w_s": round(walls[1], 4),
            "provision_2w_s": round(walls[2], 4),
            "provision_4w_s": round(walls[4], 4),
            "scaling_2w": round(walls[1] / max(walls[2], 1e-9), 2),
            "note": "even on one core (cpu_count=1) workers overlap "
                    "GIL-released buffer copies with python-side draw "
                    "bookkeeping, so >1x is real; full linear scaling "
                    "needs >= 2 cores. Bit-exactness of the parallel "
                    "split is test-enforced (tests/test_offline_bank.py)"}


def run(quick: bool = False):
    n, k, d, iters = (256, 4, 16, 2) if quick else (1024, 8, 32, 3)
    rows = [_combo_row(part, sp, n, k, d, iters) for part, sp in COMBOS]
    he_row = _paillier_wall_row()
    worker_row = _worker_scaling_row(n, k, d)
    with open(BENCH_PATH, "w") as f:
        json.dump({"rows": rows, "paillier_wall": he_row,
                   "worker_scaling": worker_row,
                   "note": "offline_cold_s = plan trace + bulk gen + AOT "
                           "compile on a first-of-its-shape pooled fit; "
                           "offline_provisioned_s = fit-time offline work "
                           "when the fit is served from a pre-provisioned "
                           "fit-plan TripleBank (generation moved to "
                           "provision_serial_s, the true offline wall). "
                           "All fits bit-exact, same seed. he_*_model_s "
                           "price ONE Protocol-2 exchange on the combo's "
                           "forward geometry under OU-2048 costs; the "
                           "legacy model now counts the loop's hidden "
                           "per-cell mask encryptions."},
                  f, indent=1)
    return rows + [he_row, worker_row]


def derived(rows):
    """Headline: worst provisioned-fit offline fraction of the cold fit
    (acceptance: <= 0.1), and the worst sparse HE model speedup."""
    combo = [r for r in rows if "provisioned_vs_cold" in r]
    he = [r["he_model_speedup"] for r in rows if "he_model_speedup" in r]
    worst = max(r["provisioned_vs_cold"] for r in combo)
    return f"prov/cold<={worst}; he_speedup>={min(he) if he else 'n/a'}"
