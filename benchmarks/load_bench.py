"""Sustained-load + chaos benchmark for the serving plane (DESIGN.md §14).

Two legs, both against the long-lived `ScoringService` (background drain
loop, bounded admission queue, deadlines, `BankReplenisher` daemon):

* **Saturation sweep** — measure the service's closed-loop base rate,
  then offer open-loop request streams at 0.5x / 1x / 2x that rate (the
  2x point is past saturation by construction). Each row reports offered
  vs achieved request rate, p50/p99 submit-to-publish latency, shed rate
  (admission-control rejections), expired deadlines, max queue depth,
  and replenish-stall occupancy (hot-path synchronous stock-out seconds
  as a fraction of the run, with the daemon's off-path top-ups next to
  it).
* **Chaos wire leg** — a real `serve_kmeans --serve-port` server process
  under a seeded `FaultyTransport` (drop/dup/delay) is killed with
  os._exit right after its 3rd journaled response and restarted on the
  same port/checkpoint; the client's rid-pinned retries must get every
  request answered exactly once, bit-exact vs a fault-free direct run.

Writes benchmarks/BENCH_load.json; wired as
`python -m benchmarks.run --only load --quick` (the per-PR smoke).
"""
from __future__ import annotations

import json
import os
import re
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import make_blobs
from repro.core.channel import FaultyTransport, SocketTransport, session_key
from repro.core.kmeans import KMeansConfig, SecureKMeans
from repro.serve import ScoringClient, ScoringResponse, ScoringService

BENCH_PATH = os.path.join(os.path.dirname(__file__), "BENCH_load.json")


def _fit(n_train: int, d: int, k: int, seed: int = 3):
    d_a = d // 2
    x = make_blobs(n_train, d, k, seed=4)
    km = SecureKMeans(KMeansConfig(k=k, iters=2, seed=seed,
                                   offline="pooled"))
    km.fit(x[:, :d_a], x[:, d_a:])
    return km, d_a


def _stream(n_requests: int, rows: int, d: int, k: int, d_a: int):
    arr = make_blobs(n_requests * rows, d, k, seed=11)
    return [(arr[i * rows:(i + 1) * rows, :d_a],
             arr[i * rows:(i + 1) * rows, d_a:]) for i in range(n_requests)]


def _service(km, d_a, d, *, ladder, copies, **kw):
    return ScoringService(km, ladder=ladder, with_scores=True,
                          d_a=d_a, d_b=d - d_a, provision_copies=copies,
                          **kw)


def _closed_loop_rate(km, d_a, d, ladder, batches, copies) -> float:
    """Base throughput: one request at a time, no think time."""
    svc = _service(km, d_a, d, ladder=ladder, copies=copies)
    svc.warm()
    t0 = time.perf_counter()
    for xa, xb in batches:
        svc.submit(xa, xb)
        svc.drain()
    return len(batches) / (time.perf_counter() - t0)


def _open_loop_row(km, d_a, d, ladder, batches, copies, offered_rps,
                   max_queue) -> dict:
    svc = _service(km, d_a, d, ladder=ladder, copies=copies,
                   max_queue=max_queue, default_deadline_s=30.0,
                   replenisher={"low_water": 1, "high_water": 3,
                                "poll_s": 0.001})
    svc.warm()
    bank_stall0 = svc.bank.replenish_seconds
    svc.start()
    t0 = time.perf_counter()
    admitted, shed = [], 0
    for i, (xa, xb) in enumerate(batches):
        lag = t0 + i / offered_rps - time.perf_counter()
        if lag > 0:
            time.sleep(lag)
        r = svc.submit(xa, xb)
        if isinstance(r, ScoringResponse):
            shed += 1                       # admission-control rejection
        else:
            admitted.append(r)
    answered = 0
    expired = 0
    for rid in admitted:
        resp = svc.response(rid, timeout=300)
        assert resp is not None, f"rid {rid} never answered"
        if resp.error is None:
            answered += 1
        elif resp.error.startswith("DeadlineExceeded"):
            expired += 1
    wall = time.perf_counter() - t0
    svc.close()
    st = svc.stats
    return {
        "leg": "open_loop",
        "offered_rps": round(offered_rps, 2),
        "achieved_rps": round(answered / wall, 2),
        "n_requests": len(batches), "answered": answered,
        "shed": shed, "shed_rate": round(shed / len(batches), 3),
        "expired": expired,
        "p50_ms": st.as_dict()["p50_ms"], "p99_ms": st.as_dict()["p99_ms"],
        "queue_max": st.max_queue_depth,
        "replenish_occupancy": round(
            (svc.bank.replenish_seconds - bank_stall0) / max(wall, 1e-9),
            4),
        "daemon_topups": svc.replenisher.topups,
        "daemon_topup_s": round(svc.replenisher.topup_seconds, 4),
        "wall_s": round(wall, 3),
    }


# ---------------------------------------------------------------------------
# chaos wire leg
# ---------------------------------------------------------------------------

def _spawn_server(args, env):
    p = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve_kmeans"] + args,
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env)
    for line in p.stdout:
        m = re.match(r"SERVING (\d+)", line)
        if m:
            return p, int(m.group(1))
    raise RuntimeError(f"server died before SERVING: rc={p.wait()}")


def _chaos_row(tmp_dir: str, n_requests: int = 6) -> dict:
    import tempfile
    from repro.core.fraud import FraudDataset

    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    ck = os.path.join(tmp_dir, "ck")
    base = ["--n-train", "200", "--d-a", "4", "--d-b", "4", "--k", "3",
            "--iters", "2", "--rungs", "16", "--serve-checkpoint-dir", ck,
            "--auth-key", "bench", "--provision-copies",
            str(2 * n_requests), "--idle-timeout", "120", "--seed", "0"]
    t_start = time.perf_counter()
    p, port = _spawn_server(base + ["--serve-port", "0",
                                    "--die-after-responses", "3"], env)
    arr = FraudDataset.synthesize(n=8 * n_requests, d_a=4, d_b=4,
                                  n_clusters=3, seed=3)
    batches = [(arr.x_a[i * 8:(i + 1) * 8], arr.x_b[i * 8:(i + 1) * 8])
               for i in range(n_requests)]
    t = SocketTransport("connect", port=port, io_timeout_s=5.0)
    ft = FaultyTransport(t, seed=11, drop=0.05, dup=0.05, delay_s=0.002)
    client = ScoringClient(ft, auth_key=session_key("bench"),
                           deadline_s=10.0, waves=2, retry_wait_s=0.2)
    got = {}
    restarts = 0
    try:
        for i, (xa, xb) in enumerate(batches):
            while True:
                try:
                    got[i] = client.score(xa, xb, rid=i)
                    break
                except Exception:
                    if restarts:
                        raise
                    p.wait(timeout=60)
                    p.stdout.read()
                    p, _port = _spawn_server(
                        base + ["--serve-port", str(port)], env)
                    restarts += 1
        client.bye()
    finally:
        t.close()
        try:
            p.stdout.read()
            p.wait(timeout=60)
        except Exception:
            p.kill()
    wall = time.perf_counter() - t_start

    # fault-free direct reference: same deterministic fit/seeds
    km = SecureKMeans(KMeansConfig(k=3, iters=2, seed=0, offline="pooled"))
    ds = FraudDataset.synthesize(n=200, d_a=4, d_b=4, n_clusters=3, seed=0)
    res = km.fit(ds.x_a, ds.x_b)
    ref_svc = ScoringService(km, res, rungs=(16,), d_a=4, d_b=4,
                             with_scores=True,
                             provision_copies=2 * n_requests)
    ref = {}
    for xa, xb in batches:
        ref_svc.submit(xa, xb)
        ref.update({r.request_id: r for r in ref_svc.drain()})
    lost = sum(1 for i in range(n_requests) if i not in got)
    bit_exact = all(
        got[i].error is None
        and np.array_equal(got[i].labels, ref[i].labels)
        and np.array_equal(got[i].scores, ref[i].scores)
        for i in got)
    assert lost == 0 and len(got) == n_requests, "lost/dup responses"
    assert restarts == 1, "kill/restart never exercised"
    assert bit_exact, "chaos responses diverged from fault-free run"
    return {"leg": "chaos_wire", "n_requests": n_requests,
            "restarts": restarts, "lost": lost,
            "bit_exact": bool(bit_exact),
            "faults": {"dropped": ft.faults.dropped,
                       "duplicated": ft.faults.duplicated,
                       "delayed": ft.faults.delayed},
            "wall_s": round(wall, 3)}


def run(quick: bool = False):
    import tempfile
    if quick:
        kw = dict(n_train=256, d=8, k=3, ladder=(16,), rows=8,
                  n_requests=24, copies=8, max_queue=4)
    else:
        kw = dict(n_train=1024, d=16, k=5, ladder=(32, 128), rows=24,
                  n_requests=64, copies=16, max_queue=8)
    km, d_a = _fit(kw["n_train"], kw["d"], kw["k"])
    batches = _stream(kw["n_requests"], kw["rows"], kw["d"], kw["k"], d_a)
    base = _closed_loop_rate(km, d_a, kw["d"], kw["ladder"],
                             batches[:max(8, kw["n_requests"] // 4)],
                             kw["copies"])
    rows = [{"leg": "closed_loop_base", "base_rps": round(base, 2),
             "ladder": list(kw["ladder"]), "rows_per_request": kw["rows"]}]
    for mult in (0.5, 1.0, 2.0):        # 2x is past saturation
        rows.append(_open_loop_row(km, d_a, kw["d"], kw["ladder"], batches,
                                   kw["copies"], mult * base,
                                   kw["max_queue"]))
    with tempfile.TemporaryDirectory() as td:
        rows.append(_chaos_row(td, n_requests=6))
    with open(BENCH_PATH, "w") as f:
        json.dump({"rows": rows,
                   "note": "Serving-plane load + chaos: open-loop offered "
                           "rates at 0.5x/1x/2x the measured closed-loop "
                           "base (2x past saturation; shed_rate is "
                           "admission-control rejections at max_queue, "
                           "replenish_occupancy the hot-path stock-out "
                           "stall fraction with the BankReplenisher "
                           "daemon's top-ups beside it), plus a two-"
                           "process kill/restart chaos leg asserting "
                           "exactly-once bit-exact responses."},
                  f, indent=1)
    return rows


def derived(rows):
    """Headline: achieved req/s at the past-saturation (2x) offered load."""
    sat = [r for r in rows if r.get("leg") == "open_loop"]
    return sat[-1]["achieved_rps"] if sat else ""
