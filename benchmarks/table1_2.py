"""Paper Tables 1 & 2: runtime (min) and communication (MB) vs M-Kmeans,
synthetic data, d=2, t=10, l=64, LAN.

Our columns are measured (online wall-clock on this host + exact protocol
traffic; offline = trusted-dealer wall + OT-modelled traffic/time). The
M-Kmeans column reproduces the paper's reported numbers for reference — its
artifact is C++/network-bound and not runnable here; the comparison target
is the ratio structure (online ~5-6x cheaper than total, same order overall).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_blobs
from repro.core.channel import LAN
from repro.core.kmeans import KMeansConfig, SecureKMeans

# paper-reported M-Kmeans totals (Table 1: minutes, Table 2: MB)
PAPER_MKMEANS_TIME = {(10**4, 2): 1.92, (10**4, 5): 5.81,
                      (10**5, 2): 18.02, (10**5, 5): 58.09}
PAPER_MKMEANS_COMM = {(10**4, 2): 5118, (10**4, 5): 18632,
                      (10**5, 2): 47342, (10**5, 5): 192192}
PAPER_OURS_TIME = {(10**4, 2): (0.33, 1.61), (10**4, 5): (0.94, 4.70),
                   (10**5, 2): (3.12, 15.19), (10**5, 5): (9.06, 48.39)}
PAPER_OURS_COMM = {(10**4, 2): (1084, 3660), (10**4, 5): (3156, 12900),
                   (10**5, 2): (14147, 32598), (10**5, 5): (33572, 131243)}


def run(quick: bool = False):
    rows = []
    sizes = [10**4] if quick else [10**4, 10**5]
    for n in sizes:
        for k in (2, 5):
            x = make_blobs(n, 2, k, seed=1)
            res = SecureKMeans(KMeansConfig(k=k, iters=10, seed=3)
                               ).fit(x[:, :1], x[:, 1:])
            online_b = res.log.total_bytes("online")
            offline_b = res.log.total_bytes("offline")
            est = res.wan_lan_estimate(LAN)
            rows.append({
                "n": n, "k": k,
                "online_s_meas": round(res.online_seconds, 2),
                "offline_dealer_s": round(res.offline_dealer_seconds, 2),
                "offline_ot_model_s": round(
                    res.offline_modelled_ot_seconds, 2),
                "online_MB": round(online_b / 2**20, 1),
                "offline_MB": round(offline_b / 2**20, 1),
                "lan_online_s": round(est["online_s"], 2),
                "lan_total_s": round(est["total_s"], 2),
                "paper_ours_time_min": PAPER_OURS_TIME[(n, k)],
                "paper_mkmeans_time_min": PAPER_MKMEANS_TIME[(n, k)],
                "paper_ours_comm_MB": PAPER_OURS_COMM[(n, k)],
                "paper_mkmeans_comm_MB": PAPER_MKMEANS_COMM[(n, k)],
            })
    return rows


def derived(rows):
    """Headline: online share of total traffic (paper: offline dominates)."""
    fracs = [r["online_MB"] / max(r["online_MB"] + r["offline_MB"], 1e-9)
             for r in rows]
    return float(np.mean(fracs))
