"""Paper Fig. 3: vectorized vs numerical (pre-vectorization) protocol,
distance step, WAN, n=1000 k=4 t=20, d in {2,4,6,8}.

Payload bytes are identical by construction; the win is ROUNDS (one
interaction per matmul vs one per scalar product), which under 40 ms WAN RTT
is the whole story — exactly the paper's argument."""
from __future__ import annotations

from benchmarks.common import make_blobs
from repro.core.channel import WAN
from repro.core.kmeans import KMeansConfig, SecureKMeans


def run():
    rows = []
    for d in (2, 4, 6, 8):
        x = make_blobs(1000, d, 4, seed=2)
        half = d // 2
        out = {}
        for vec in (True, False):
            res = SecureKMeans(KMeansConfig(k=4, iters=20, seed=3,
                                            vectorized=vec)
                               ).fit(x[:, :half], x[:, half:])
            on = res.log.by_tag("online")
            b, r = on.get("S1", (0, 0))
            out["vec" if vec else "num"] = WAN.time_s(b, r)
            out[("vec" if vec else "num") + "_rounds"] = r
        rows.append({"d": d,
                     "online_wan_s_vectorized": round(out["vec"], 2),
                     "online_wan_s_numerical": round(out["num"], 2),
                     "rounds_vectorized": out["vec_rounds"],
                     "rounds_numerical": out["num_rounds"],
                     "speedup": round(out["num"] / max(out["vec"], 1e-9), 1)})
    return rows


def derived(rows):
    # paper: improvement grows with d
    return rows[-1]["speedup"]
