"""Paper Sec 5.6 deployment: payment company + merchant jointly detect
fraudulent transactions with secure K-means; nothing but the output is
revealed. Shows the single-party vs joint-modelling gap.

Scoring runs through the secure `SecureKMeans.score` path: each
transaction's squared distance to its assigned centroid is computed on
SHARES against the secret-shared model, and only those scores are revealed
— never the centroids or per-transaction cluster labels. (The old
reconstruct-the-model behavior survives behind `reveal_model=True`.)

    PYTHONPATH=src python examples/fraud_detection.py
"""
from repro.core.fraud import (FraudDataset, detect_outliers, fraud_scores,
                              jaccard, run_plaintext_fraud, run_secure_fraud)


def main():
    ds = FraudDataset.synthesize(n=4000, d_a=18, d_b=24, n_clusters=5,
                                 frac_outlier=0.02, seed=3)
    j_joint, res = run_secure_fraud(ds, k=5, iters=10, seed=3)
    j_plain = run_plaintext_fraud(ds, k=5, iters=10, seed=3)
    j_single = run_plaintext_fraud(ds, k=5, iters=10, seed=3,
                                   party_a_only=True)
    print("Jaccard vs ground-truth fraud set")
    print(f"  secure joint, secure scoring : {j_joint:.3f}")
    print(f"  plaintext joint (oracle)     : {j_plain:.3f}")
    print(f"  payment-company only         : {j_single:.3f}")
    print(f"(paper: ours 0.86, M-Kmeans 0.83, single-party 0.62)")
    print(f"online traffic {res.log.total_bytes('online')/2**20:.1f} MB "
          f"in {res.log.total_rounds('online')} rounds")

    # the revealed-model escape hatch scores identically up to fixed-point
    # error but reconstructs centroids + labels in plaintext to do it
    leaky = fraud_scores(None, res, ds, reveal_model=True)
    j_leaky = jaccard(detect_outliers(leaky, 0.02), ds.y_outlier)
    print(f"  reveal_model=True hatch      : {j_leaky:.3f} "
          "(same quality, leaks the model)")


if __name__ == "__main__":
    main()
