"""Sparsity-aware mode (paper Sec 4.3): one-hot-heavy features, Protocol 2
(HE x SS sparse matmul) replacing the dense Beaver path. Compares online
traffic of both modes on the same data — the paper's headline win.

    PYTHONPATH=src python examples/sparse_vertical.py
"""
import numpy as np

from repro.core.channel import WAN
from repro.core.kmeans import KMeansConfig, SecureKMeans


def main():
    rng = np.random.default_rng(11)
    n, d, k, sparsity = 3000, 256, 3, 0.9
    centers = rng.uniform(-2, 2, (k, d))
    lab = rng.integers(0, k, n)
    x = (centers[lab] + rng.normal(0, 0.3, (n, d)))
    x *= rng.random((n, d)) >= sparsity          # 90% zeros (one-hot-ish)

    half = d // 2
    out = {}
    for sparse in (False, True):
        cfg = KMeansConfig(k=k, iters=5, seed=2, sparse=sparse)
        res = SecureKMeans(cfg).fit(x[:, :half], x[:, half:])
        out[sparse] = res
        mode = "Protocol-2 (HE x SS)" if sparse else "dense Beaver SS"
        b = res.log.total_bytes("online")
        print(f"{mode:22s}: online {b/2**20:8.1f} MB, "
              f"WAN est {WAN.time_s(b, res.log.total_rounds('online')) + res.he_seconds:7.1f}s, "
              f"HE cpu {res.he_seconds:6.1f}s")
    agree = (out[True].labels_plain() == out[False].labels_plain()).mean()
    print(f"assignment agreement dense vs sparse: {agree:.1%}")


if __name__ == "__main__":
    main()
