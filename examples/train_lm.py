"""End-to-end driver: train a ~100M-param granite-family model for a few
hundred steps on the synthetic bigram stream, with atomic checkpointing and
auto-resume. (The same driver, pointed at a production mesh and the full
config, is the cluster entrypoint — see launch/train.py.)

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses

from repro.configs.base import ModelConfig, ScanGroup
from repro.configs import granite_34b  # noqa: F401  (registers the arch)
from repro.configs.base import _REGISTRY, ArchSpec
from repro.launch.train import run

# ~100M-param granite-family config (d=768, 12L, GQA kv=1, tied head)
CFG_100M = ModelConfig(
    name="granite-100m", d_model=768, n_heads=12, n_kv_heads=1,
    d_ff=3072, vocab_size=8192,
    groups=(ScanGroup(("attn",), 12),),
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    spec = _REGISTRY["granite-34b"]
    _REGISTRY["granite-100m"] = ArchSpec(config=CFG_100M, reduced=CFG_100M)
    out = run("granite-100m", reduced=True, steps=args.steps,
              batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
              ckpt_every=100, lr=6e-4, log_every=20)
    print(f"\nfinal loss {out['final_loss']:.3f} after {out['steps_run']} "
          f"steps (resumed from {out['resumed_from']}); "
          f"p50 {out['p50_ms']:.0f} ms, p95 {out['p95_ms']:.0f} ms/step")
    first = out["losses"][0] if out["losses"] else float("nan")
    print(f"loss improved {first:.3f} -> {out['final_loss']:.3f}")


if __name__ == "__main__":
    main()
