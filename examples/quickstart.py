"""Quickstart: two parties jointly cluster vertically-partitioned data with
the privacy-preserving K-means protocol, reconstruct only the result, and
compare against plaintext Lloyd.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.channel import LAN, WAN
from repro.core.kmeans import KMeansConfig, SecureKMeans, plaintext_kmeans


def main():
    rng = np.random.default_rng(7)
    n, d, k = 2000, 8, 4
    centers = rng.uniform(-4, 4, (k, d))
    labels = rng.integers(0, k, n)
    x = centers[labels] + rng.normal(0, 0.3, (n, d))

    # party A = payment company (first 4 features), B = merchant (last 4)
    x_a, x_b = x[:, :4], x[:, 4:]

    cfg = KMeansConfig(k=k, iters=10, partition="vertical", seed=1)
    res = SecureKMeans(cfg).fit(x_a, x_b)

    _, lab_ref = plaintext_kmeans(x, k, 10, seed=1)
    agree = (res.labels_plain() == lab_ref).mean()

    print(f"samples={n} d={d} k={k}  iters={res.iters_run}")
    print(f"agreement with plaintext K-means: {agree:.1%}")
    print(f"online  : {res.online_seconds:.2f}s wall, "
          f"{res.log.total_bytes('online')/2**20:.1f} MB, "
          f"{res.log.total_rounds('online')} rounds")
    print(f"offline : dealer {res.offline_dealer_seconds:.2f}s "
          f"(OT-model {res.offline_modelled_ot_seconds:.1f}s), "
          f"{res.log.total_bytes('offline')/2**20:.1f} MB")
    for net in (LAN, WAN):
        est = res.wan_lan_estimate(net)
        print(f"{net.name}: online {est['online_s']:.1f}s, "
              f"total {est['total_s']:.1f}s")


if __name__ == "__main__":
    main()
